"""Fork-creation benchmarks: Fig 5 (latency vs log length), Fig 6 (parent
throughput during fork creation), Fig 11 (promote latency), Fig 10 (recursive
lookup vs depth), §6.5 (metadata memory)."""

from __future__ import annotations

import time
from typing import List

from repro.core import BoltSystem
from repro.core.metadata import MetadataState

from .common import RECORD, Row, fill_root, timeit


def bench_fork_latency() -> List[Row]:
    """Fig 5: Bolt zero-metadata-copy vs BoltMetaCpy, varying parent length."""
    rows: List[Row] = []
    for n in (1_000, 10_000, 100_000, 1_000_000):
        bolt = BoltSystem()
        log = fill_root(bolt, "r", n)
        forks = []
        us = timeit(lambda: forks.append(log.sfork()), n=5)
        rows.append((f"fig5/fork_latency/bolt/n={n}", us, "zero-metadata-copy"))
    for n in (1_000, 10_000, 100_000):
        mc = BoltSystem(fork_mode="metacopy")
        log = fill_root(mc, "r", n)
        us = timeit(lambda: log.sfork(), n=3)
        rows.append((f"fig5/fork_latency/metacopy/n={n}", us, "copies index"))
    return rows


def bench_fork_impact() -> List[Row]:
    """Fig 6: parent append throughput while 100 forks are created."""
    rows: List[Row] = []
    for mode, tag in (("zerocopy", "bolt"), ("metacopy", "metacopy")):
        sys_ = BoltSystem(fork_mode=mode)
        log = fill_root(sys_, "r", 50_000)
        batch = [RECORD] * 64
        # steady state
        t0 = time.perf_counter()
        for _ in range(50):
            log.append_batch(batch)
        steady = 50 * 64 / (time.perf_counter() - t0)
        # while creating 100 forks interleaved
        t0 = time.perf_counter()
        for i in range(100):
            log.append_batch(batch)
            log.sfork()
        during = 100 * 64 / (time.perf_counter() - t0)
        rows.append((f"fig6/append_tput/{tag}/steady", 1e6 / steady,
                     f"{steady:.0f} rec/s"))
        rows.append((f"fig6/append_tput/{tag}/during_forks", 1e6 / during,
                     f"{during:.0f} rec/s ({during / steady:.2f}x of steady)"))
    return rows


def bench_promote() -> List[Row]:
    """Fig 11: promote latency vs records-after-fork-point; copy (paper §5.6)
    vs splice (beyond-paper O(1)) vs temporary-log data copy."""
    rows: List[Row] = []
    for n_after in (1_000, 10_000, 100_000):
        for mode in ("copy", "splice"):
            sys_ = BoltSystem(promote_mode=mode)
            log = fill_root(sys_, "r", 10_000)
            fork = log.cfork(promotable=True)
            batch = [RECORD] * 500
            for _ in range(n_after // 500):
                fork.append_batch(batch)
            t0 = time.perf_counter()
            fork.promote()
            us = (time.perf_counter() - t0) * 1e6
            rows.append((f"fig11/promote/{mode}/n_after={n_after}", us,
                         "metadata-only"))
        # temporary-log approach: copy the DATA records across logs
        sys_ = BoltSystem()
        log = fill_root(sys_, "r", 10_000)
        tmp = sys_.create_log("tmp")
        batch = [RECORD] * 500
        for _ in range(n_after // 500):
            tmp.append_batch(batch)
        t0 = time.perf_counter()
        for lo in range(0, n_after, 500):
            recs = tmp.read(lo, lo + 500)
            log.append_batch(recs)
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"fig11/promote/datacopy/n_after={n_after}", us,
                     "temporary-log (no stateful validation)"))
    return rows


def bench_lookup_depth() -> List[Row]:
    """Fig 10: recursive HLI lookup latency vs cFork nesting depth.

    The flattened-view cache is disabled here on purpose — this figure
    measures the paper's recursive resolver; `bench_read` (DESIGN.md §10)
    measures cached-vs-uncached side by side."""
    rows: List[Row] = []
    state = MetadataState(view_cache=False)
    root = state.apply(("create_root", "r"))
    per_level = 20_000
    batch = 512
    log_id = root
    depths = {0: root}
    for depth in range(1, 8):
        for start in range(0, per_level, batch):
            state.apply(("append", log_id, f"o{depth}-{start}",
                         tuple(range(0, batch * 8, 8)), tuple([8] * batch)))
        log_id = state.apply(("cfork", log_id, False))
        depths[depth] = log_id
    # query the deepest log at a position that recurses to the root
    deepest = depths[7]
    for depth_hit in (1, 3, 5, 7):
        # position inside the level `7 - depth_hit` ancestor's local records
        pos = (7 - depth_hit) * per_level + per_level // 2
        us = timeit(lambda: state.read_spans(deepest, pos, pos + 1), n=2000)
        rows.append((f"fig10/lookup/depth={depth_hit}", us,
                     "recursive HLI lookup"))
    return rows


def bench_metadata_memory() -> List[Row]:
    """§6.5: metadata bytes for many cForks of a busy root: naive duplication
    vs Bolt (run-compressed HLI + tail-only updates)."""
    rows: List[Row] = []
    # Bolt: 1000 cForks, 1M records
    state = MetadataState(cf_mode="ltt")
    root = state.apply(("create_root", "r"))
    for _ in range(1000):
        state.apply(("cfork", root, False))
    batch = 1024
    offs = tuple(range(0, batch * 8, 8))
    lens = tuple([8] * batch)
    for i in range(1_000_000 // batch):
        state.apply(("append", root, f"o{i}", offs, lens))
    bolt_bytes = state.metadata_bytes()
    rows.append(("mem65/bolt/1000forks_1M", float(bolt_bytes),
                 f"{bolt_bytes / 1e6:.1f} MB"))
    # naive: scaled run (100 forks x 100k records), extrapolated linearly
    state = MetadataState(cf_mode="naive")
    root = state.apply(("create_root", "r"))
    for _ in range(100):
        state.apply(("cfork", root, False))
    for i in range(100_000 // batch):
        state.apply(("append", root, f"o{i}", offs, lens))
    naive_bytes = state.metadata_bytes()
    scaled = naive_bytes * 10 * 10  # x10 forks, x10 records
    rows.append(("mem65/naive/100forks_100k", float(naive_bytes),
                 f"{naive_bytes / 1e6:.1f} MB measured"))
    rows.append(("mem65/naive/extrapolated_1000forks_1M", float(scaled),
                 f"{scaled / 1e9:.2f} GB (x{scaled / max(bolt_bytes, 1):.0f} of Bolt)"))
    return rows
