"""Speculative decoding as log speculation vs sequential decode-and-append
(DESIGN.md §17) — the serving-shaped workload.

Scenario: decoders serve requests onto one shared ``responses`` root while a
monitor agent annotates the same stream every ``PUMP_PERIOD`` seconds of
*simulated* time (the paper's agents-on-streams loop: model output and agent
traffic share a log). Both modes run REAL AgileLog operations against one
BoltSystem — every re-anchor comes from actual tail advancement sequenced
through the metadata layer — while a deterministic clock books two kinds of
service time on the decoder's critical path:

* **model steps** from ``repro.serve.costs``: per-step roofline times derived
  the same way ``launch/dryrun.py`` scores training shapes — qwen3-8b target,
  smollm-135m draft, hlo_cost ``Cost`` geometry through the v5e roofline.
  One qwen3-8b decode step is ~20ms (weights-streaming memory-bound), one
  draft step ~0.5ms, and a k-token verify pass costs ~one decode step — the
  classic speculative-decoding asymmetry.
* **log operations** from :class:`ServiceTimes`, exactly as ``bench_agent``
  books them: PUT-backed appends, metadata rounds, zero-copy replays.

The two serving loops (both over the SAME deterministic token stream — greedy
speculative decoding is exact, so both emit byte-identical responses):

* ``sequential``  — one target decode step AND one durable per-token append
  (each token acked to subscribers as produced).
* ``speculative`` — each k-token draft rollout is a ``log.speculate()``
  session (fork = sequence branch, ``promote_if`` = acceptance, auto-rebase =
  re-anchor over the monitor's interleaved records); one batched commit per
  rollout amortizes the per-token PUT+sequencing the baseline pays.

Acceptance (ISSUE 9): accepted-token throughput >= 1.5x sequential at draft
acceptance >= 0.7. ``BENCH_QUICK=1`` shrinks the run ~4x for CI smoke.
"""

from __future__ import annotations

import hashlib
import os
from typing import List

from repro.core import BoltSystem
from repro.core.sim import OpTally, ServiceTimes
from repro.configs import get_config
from repro.serve.costs import ServeCosts
from repro.serve.speculative import (SpeculativeDecoder, decode_response,
                                     sequential_decode_on_log)
from repro.streams.records import encode_record

from .common import Row

QUICK = os.environ.get("BENCH_QUICK", "") not in ("", "0")

S = ServiceTimes()
COSTS = ServeCosts.for_models(get_config("qwen3-8b"),
                              get_config("smollm-135m"),
                              batch=1, context=512)

K = 4                       # draft depth per rollout
VOCAB = 997
PROMPT_LEN = 32
TOKEN_BYTES = 48            # encoded (id, seq, tok) record size (approx)
PUMP_PERIOD = 10e-3         # one monitor record per 10ms of simulated time
MONITOR_REC = encode_record({"id": "__monitor", "eos": True, "n": 0})


def _next_token(prefix: List[int]) -> int:
    """Deterministic synthetic target: greedy token = hash of the prefix."""
    h = hashlib.blake2b(b"".join(t.to_bytes(2, "big") for t in prefix[-16:]),
                        digest_size=4).digest()
    return int.from_bytes(h[:2], "big") % VOCAB


class _Target:
    def verify(self, prefix: List[int], draft: List[int]) -> List[int]:
        out, p = [], list(prefix)
        for i in range(len(draft) + 1):
            out.append(_next_token(p))
            if i < len(draft):
                p.append(draft[i])
        return out


class _Draft:
    """Agrees with the target except where the prefix hash says otherwise
    (~6% of positions) — a deterministic stand-in for a well-trained draft
    model's ~0.94 per-token acceptance."""

    def propose(self, prefix: List[int], k: int) -> List[int]:
        out, p = [], list(prefix)
        for _ in range(k):
            t = _next_token(p)
            h = hashlib.blake2b(b"d" + len(p).to_bytes(4, "big")
                                + t.to_bytes(2, "big"), digest_size=2).digest()
            if h[0] % 16 == 0:
                t = (t + 1) % VOCAB
            out.append(t)
            p.append(t)
        return out


class _ServeClock:
    """Deterministic decoder-side clock (same shape as bench_agent's): each
    op advances simulated time by its modeled cost, then lets the monitor
    catch up to the new time — so mid-session tail movement (and therefore
    re-anchoring) emerges from real sequencing at honest rates."""

    def __init__(self, pump) -> None:
        self.t = 0.0
        self._pump = pump

    def op(self, cost: float) -> None:
        self.t += cost
        self._pump(self.t)

    def model(self, seconds: float) -> None:
        """One model invocation: host dispatch + roofline step time."""
        self.op(S.serve_dispatch + seconds)

    def propose(self) -> None:
        self.op(S.metadata_op + S.net_rtt)

    def put_append(self, nbytes: int) -> None:
        self.op(S.broker_cpu_per_req + S.broker_cpu_per_kb * nbytes / 1024
                + S.store_put_base + S.store_put_per_kb * nbytes / 1024
                + S.metadata_op + S.net_rtt)

    def replay_append(self) -> None:
        self.op(S.broker_cpu_per_req + S.metadata_op + S.net_rtt)


def _book_rollout(clock: _ServeClock, r) -> None:
    """Book the log-side cost of what one rollout actually did: the opening
    session (cfork round, one batched PUT append, promote_if round), each
    re-anchor (squash + cfork + zero-copy replay + retried promote_if), and
    — for rejected rollouts — the abort squash plus the second session that
    commits the accepted prefix + correction."""
    clock.propose()                                   # cfork
    clock.put_append((r.drafted or 1) * TOKEN_BYTES)  # draft batch PUT
    if not r.rejected and r.drafted:
        clock.put_append(TOKEN_BYTES)                 # bonus token append
    clock.propose()                                   # promote_if
    for _ in range(r.rebases):
        clock.propose()                               # squash stale fork
        clock.propose()                               # fresh cfork
        clock.replay_append()                         # zero-copy suffix
        clock.propose()                               # retried promote_if
    if r.rejected:
        clock.propose()                               # abort squash
        clock.propose()                               # second-session cfork
        clock.put_append(len(r.emitted) * TOKEN_BYTES)
        clock.propose()                               # promote_if


def _run_mode(speculative: bool, n_requests: int, max_new: int) -> dict:
    system = BoltSystem(n_brokers=4, gc=True)
    root = system.create_log("responses")
    produced = [0]

    def pump(t: float) -> None:
        want = int(t / PUMP_PERIOD)
        while produced[0] < want:
            root.append(MONITOR_REC)     # withheld while a rollout holds
            produced[0] += 1

    clock = _ServeClock(pump)
    target, draft = _Target(), _Draft()
    stats = system.serve_stats
    before = OpTally.capture(system)
    t0 = clock.t

    prompts = [[(7 * r + i) % VOCAB for i in range(PROMPT_LEN)]
               for r in range(n_requests)]
    outputs = {}
    if speculative:
        dec = SpeculativeDecoder(
            target, draft, k=K, stats=stats,
            on_draft=lambda n: [clock.model(COSTS.draft_step)
                                for _ in range(n)],
            on_target=lambda p: clock.model(COSTS.verify(p - 1)))
        for r, prompt in enumerate(prompts):
            clock.model(COSTS.prefill_per_token * PROMPT_LEN)
            res = dec.decode_request(root, f"req-{r}", prompt, max_new)
            for roll in res.rollouts:
                _book_rollout(clock, roll)
            clock.put_append(len(MONITOR_REC))        # EOS record
            outputs[f"req-{r}"] = res.tokens
    else:
        for r, prompt in enumerate(prompts):
            clock.model(COSTS.prefill_per_token * PROMPT_LEN)
            outputs[f"req-{r}"] = sequential_decode_on_log(
                target, root, f"req-{r}", prompt, max_new, stats=stats,
                on_target=lambda p: clock.model(COSTS.decode_step))
            # per-token appends ride the clock too: one PUT + round each
            for _ in range(max_new):
                clock.put_append(TOKEN_BYTES)
            clock.put_append(len(MONITOR_REC))        # EOS record
    elapsed = clock.t - t0
    tally = OpTally.capture(system).delta(before)
    view = decode_response(root.read(0, root.visible_tail))
    for rid, toks in outputs.items():
        assert view[rid] == toks, f"stream/output divergence for {rid}"
    tokens = n_requests * max_new
    return {
        "tokens_per_s": tokens / elapsed,
        "ms_per_token": elapsed / tokens * 1e3,
        "tokens": tokens,
        "acceptance": stats.acceptance,
        "model_steps": stats.model_steps,
        "draft_steps": stats.draft_steps,
        "rollouts": stats.rollouts,
        "rollouts_rejected": stats.rollouts_rejected,
        "reanchors": stats.reanchors,
        "monitor_records": produced[0],
        "puts_per_token": (tally.puts - produced[0]) / max(1, tokens),
        "outputs": outputs,
    }


def bench_serve() -> List[Row]:
    n_requests = 3 if QUICK else 6
    max_new = 24 if QUICK else 32

    seq = _run_mode(speculative=False, n_requests=n_requests, max_new=max_new)
    spec = _run_mode(speculative=True, n_requests=n_requests, max_new=max_new)
    # greedy speculative decoding is exact: both modes must emit the same
    # byte stream, so the throughput ratio compares equal work
    assert spec["outputs"] == seq["outputs"], "speculative != sequential"

    speedup = spec["tokens_per_s"] / seq["tokens_per_s"]
    rows: List[Row] = []
    rows.append(("serve/sequential/ms_per_token", seq["ms_per_token"],
                 f"{seq['tokens']} tokens, one ~{COSTS.decode_step*1e3:.1f}ms "
                 f"qwen3-8b decode step + one durable append per token, "
                 f"{seq['monitor_records']} monitor records interleaved"))
    rows.append(("serve/speculative/ms_per_token", spec["ms_per_token"],
                 f"{spec['tokens']} tokens in {spec['rollouts']} speculate() "
                 f"rollouts (k={K}), {spec['rollouts_rejected']} aborted "
                 f"with no trace, {spec['draft_steps']} draft steps at "
                 f"~{COSTS.draft_step*1e3:.2f}ms"))
    rows.append(("serve/speculative/speedup", speedup,
                 f"{speedup:.2f}x accepted-token throughput vs sequential "
                 f"(acceptance floor >= 1.5x)"))
    rows.append(("serve/speculative/acceptance", spec["acceptance"],
                 f"draft acceptance rate (floor >= 0.7): verify pass costs "
                 f"~{COSTS.verify(K)*1e3:.1f}ms vs "
                 f"{K+1}x{COSTS.decode_step*1e3:.1f}ms sequential"))
    rows.append(("serve/speculative/puts_per_token", spec["puts_per_token"],
                 f"vs {seq['puts_per_token']:.2f} sequential: one batched "
                 f"commit per rollout amortizes the per-token PUT"))
    rows.append(("serve/sequential/puts_per_token", seq["puts_per_token"],
                 "every token is its own durable append"))
    rows.append(("serve/speculative/reanchors_per_rollout",
                 spec["reanchors"] / max(1, spec["rollouts"]),
                 f"{spec['reanchors']} auto-rebases re-anchored commits over "
                 f"{spec['monitor_records']} interleaved monitor records "
                 f"(zero-copy suffix replay)"))
    return rows
