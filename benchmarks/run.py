"""Benchmark harness — one benchmark per paper table/figure (DESIGN.md §8).

Prints ``name,us_per_call,derived`` CSV. Usage:
    PYTHONPATH=src python -m benchmarks.run [--only substring] [--json PATH]

``--json`` additionally dumps ``{row_name: value}`` to PATH (e.g.
``BENCH_append.json``) so the perf trajectory across PRs records real numbers.
"""

from __future__ import annotations

import argparse
import json
import sys
import traceback

from .bench_agent import bench_agent
from .bench_agents import bench_agents
from .bench_append import bench_append
from .bench_cforks import bench_cfork_ablation, bench_many_cforks
from .bench_chaos import bench_chaos
from .bench_forks import (bench_fork_impact, bench_fork_latency,
                          bench_lookup_depth, bench_metadata_memory,
                          bench_promote)
from .bench_gc import bench_gc
from .bench_isolation import bench_isolation
from .bench_meta import bench_meta
from .bench_pipeline import bench_pipeline
from .bench_read import bench_read
from .bench_roofline import bench_roofline
from .bench_serve import bench_serve

ALL = [
    ("fig5_fork_latency", bench_fork_latency),
    ("fig6_fork_impact", bench_fork_impact),
    ("fig7_isolation", bench_isolation),
    ("fig8_many_cforks", bench_many_cforks),
    ("fig9_cfork_ablation", bench_cfork_ablation),
    ("fig10_lookup_depth", bench_lookup_depth),
    ("fig11_promote", bench_promote),
    ("mem65_metadata_memory", bench_metadata_memory),
    ("fig12_14_agents", bench_agents),
    ("append_group_commit", bench_append),
    ("read_path", bench_read),
    ("meta_path", bench_meta),
    ("agent_sessions", bench_agent),
    ("segment_gc", bench_gc),
    ("chaos_availability", bench_chaos),
    ("data_pipeline", bench_pipeline),
    ("roofline", bench_roofline),
    ("serving", bench_serve),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--json", default=None,
                    help="also write {row_name: value} JSON to this path")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    failed = []
    results = {}
    for name, fn in ALL:
        if args.only and args.only not in name:
            continue
        try:
            for row_name, val, derived in fn():
                print(f"{row_name},{val:.3f},{derived}", flush=True)
                results[row_name] = val
        except Exception as e:  # keep the harness running
            failed.append(name)
            traceback.print_exc(file=sys.stderr)
            print(f"{name}/ERROR,0.0,{type(e).__name__}: {e}", flush=True)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
