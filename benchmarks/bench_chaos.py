"""Availability-under-faults benchmark (DESIGN.md §15).

The §15 fault plane makes failure a first-class, *deterministic* input: the
same seed replays the same store errors, crash windows, and kill schedule on
every machine. This scenario runs one append/read workload twice under the
DES clock (§8) — once fault-free, once with 1% store-op noise plus a
scheduled broker kill and a scheduled metadata-leader kill — and reports:

* **Goodput ratio** — acked records per modeled second, faulted over
  fault-free. Retry backoff (`RetryStats.backoff_time`) is charged to the
  modeled completion times, so every failed attempt and every jittered
  pause costs availability. Acceptance (CI ``--key-min``): >= 0.9x.
* **p99 ack-latency ratio** — the tail cost of transparent recovery: a
  faulted append pays its extra PUT attempts and backoff pauses, and the
  ratio is dimensionless, so CI diffs it against the committed baseline.
* **MTTR** — mean time to repair after each scheduled kill: the client
  sticks to one broker (real clients hold connections), discovers the death
  by a failed attempt, and the fleet's retry layer (§15) fails over through
  ``live_broker``; MTTR is the modeled completion of the first ack after
  the kill minus the kill time. The leader kill measures the metadata
  layer's re-election path the same way. Acceptance (CI ``--key-max``):
  both MTTRs stay under 50 modeled ms.

Both runs share the workload, the DES service model, and the arrival
process; only the fault plane differs — the ratios isolate the cost of the
faults themselves. ``BENCH_QUICK=1`` shrinks the run ~4x for CI smoke.
"""

from __future__ import annotations

import os
from typing import List, Optional

from repro.core import BoltSystem, FaultConfig, RetryPolicy
from repro.core.errors import BrokerCrashed
from repro.core.sim import Resource, ServiceTimes, Simulator, summarize

from .common import Row

QUICK = os.environ.get("BENCH_QUICK", "") not in ("", "0")

REC = b"c" * 1024
N_OPS = 400 if QUICK else 1600
RATE = 600.0                      # appends per modeled second
READ_EVERY = 8                    # interleaved reads exercise the GET path
KILL_BROKER_AT = 0.30             # fraction of the arrival span
KILL_LEADER_AT = 0.60
STORE_NOISE = 0.01                # ISSUE 7 acceptance: 1% store-op failure


def _build(faulted: bool) -> BoltSystem:
    cfg = None
    if faulted:
        span = N_OPS / RATE
        cfg = FaultConfig(
            seed=0xC4A05,
            store_put_error=STORE_NOISE,
            store_get_error=STORE_NOISE,
            store_delete_error=STORE_NOISE,
            # the kill targets broker 0 — the sticky client's connection —
            # so the MTTR path includes the detection failure, not a free
            # re-route around a broker the client never talked to
            schedule=((span * KILL_BROKER_AT, "kill_broker", 0),
                      (span * KILL_LEADER_AT, "kill_leader", None)))
    system = BoltSystem(n_brokers=4, n_meta_replicas=3, faults=cfg,
                        retry=RetryPolicy(attempts=8))
    # the DES hooks ride on the brokers (§8): every PUT/GET books service
    # time and queues on the shared store pool, so completion times are
    # modeled, deterministic, and machine-portable
    sim = Simulator()
    service = ServiceTimes()
    store_res = Resource(servers=64)
    for b in system.brokers:
        b.sim = sim
        b.service = service
        b.store_resource = store_res
    return system


class _StickyClient:
    """A client that holds one broker connection (as real clients do) and
    re-connects only after an attempt observes the death — so a broker kill
    costs a detection failure plus the §15 failover/backoff, all of which
    lands in the MTTR measurement instead of being routed around for free."""

    def __init__(self, system: BoltSystem) -> None:
        self.system = system
        self.cur = system.brokers[0]

    def _attempt(self, fn):
        def attempt(_a):
            b = self.cur
            if b.broker_id in self.system._dead:
                # re-connect for the NEXT attempt; THIS attempt is the
                # failed detection RPC the retry layer pays backoff for
                self.cur = self.system.live_broker(b)
                raise BrokerCrashed("client-held broker is dead",
                                    broker_id=b.broker_id)
            return fn(b)
        return self.system._retrying(attempt)

    def append(self, log_id: int, t: float):
        return self._attempt(lambda b: b.append(log_id, [REC], arrival=t))

    def read(self, log_id: int, lo: int, hi: int, t: float):
        return self._attempt(lambda b: b.read(log_id, lo, hi, arrival=t))


def _run(faulted: bool) -> dict:
    system = _build(faulted)
    root = system.metadata.propose(("create_root", "chaos"))
    client = _StickyClient(system)
    span = N_OPS / RATE
    kills = ([(span * KILL_BROKER_AT, "broker"),
              (span * KILL_LEADER_AT, "leader")] if faulted else [])
    mttr: dict = {}
    pending_kill: Optional[tuple] = None
    lat: List[float] = []
    makespan = 0.0
    read_hi = 0
    for i in range(N_OPS):
        t = i / RATE
        if faulted:
            if kills and t >= kills[0][0]:
                pending_kill = kills.pop(0)
            system.faults.advance(t)
        backoff0 = system.retry_stats.backoff_time
        if READ_EVERY and i % READ_EVERY == READ_EVERY - 1 and read_hi:
            _, done = client.read(root, max(0, read_hi - 16), read_hi, t)
        else:
            _, done = client.append(root, t)
            read_hi += 1
            # jittered pauses advance the client's clock even though the
            # DES store pool never sees them: charge them to the ack
            done += system.retry_stats.backoff_time - backoff0
            lat.append(done - t)
            if pending_kill is not None:
                mttr[pending_kill[1]] = done - pending_kill[0]
                pending_kill = None
        makespan = max(makespan, done)
    state = system.metadata.state
    assert state.tails.get(root)[0] == read_hi, "lost acked appends"
    out = {"p99": summarize(sorted(lat))[2],
           "goodput": read_hi / makespan,
           "retries": system.retry_stats.retries,
           "backoff": system.retry_stats.backoff_time,
           "mttr": mttr}
    if faulted:
        out["injected"] = system.faults.total_injected
        out["elections"] = system.metadata.elections
        out["failovers"] = system.broker_failovers
    return out


def bench_chaos() -> List[Row]:
    base = _run(faulted=False)
    chaos = _run(faulted=True)
    rows: List[Row] = []
    rows.append(("chaos/fault_free/p99_ack_ms", base["p99"] * 1e3,
                 f"{N_OPS} ops at {RATE:.0f}/s on the DES clock, no plane "
                 "attached (the byte-identical pre-§15 path)"))
    rows.append(("chaos/faulted/p99_ack_ms", chaos["p99"] * 1e3,
                 f"{STORE_NOISE * 100:.0f}% store noise + broker kill + "
                 f"leader kill: {chaos['injected']} faults injected, "
                 f"{chaos['retries']} retries, "
                 f"{chaos['backoff'] * 1e3:.1f}ms total backoff charged"))
    rows.append(("chaos/p99_ack_ratio", chaos["p99"] / base["p99"],
                 "tail cost of transparent recovery (dimensionless; CI "
                 "diffs it against the committed baseline)"))
    rows.append(("chaos/goodput_ratio", chaos["goodput"] / base["goodput"],
                 f"{chaos['goodput']:.0f}/s faulted vs {base['goodput']:.0f}/s "
                 "fault-free acked records per modeled second "
                 "(acceptance floor >= 0.9x)"))
    rows.append(("chaos/mttr/broker_kill_ms", chaos["mttr"]["broker"] * 1e3,
                 f"first ack after the scheduled broker kill: detection "
                 f"failure + §15 failover ({chaos['failovers']} staged "
                 "failovers) + backoff (ceiling 50 modeled ms)"))
    rows.append(("chaos/mttr/leader_kill_ms", chaos["mttr"]["leader"] * 1e3,
                 f"first ack after the scheduled leader kill: the metadata "
                 f"layer re-elected {chaos['elections']} time(s) inside the "
                 "propose path (ceiling 50 modeled ms)"))
    return rows
