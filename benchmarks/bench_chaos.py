"""Availability-under-faults benchmark (DESIGN.md §15).

The §15 fault plane makes failure a first-class, *deterministic* input: the
same seed replays the same store errors, crash windows, and kill schedule on
every machine. This scenario runs one append/read workload twice under the
DES clock (§8) — once fault-free, once with 1% store-op noise plus a
scheduled broker kill and a scheduled metadata-leader kill — and reports:

* **Goodput ratio** — acked records per modeled second, faulted over
  fault-free. Retry backoff (`RetryStats.backoff_time`) is charged to the
  modeled completion times, so every failed attempt and every jittered
  pause costs availability. Acceptance (CI ``--key-min``): >= 0.9x.
* **p99 ack-latency ratio** — the tail cost of transparent recovery: a
  faulted append pays its extra PUT attempts and backoff pauses, and the
  ratio is dimensionless, so CI diffs it against the committed baseline.
* **MTTR** — mean time to repair after each scheduled kill: the client
  sticks to one broker (real clients hold connections), discovers the death
  by a failed attempt, and the fleet's retry layer (§15) fails over through
  ``live_broker``; MTTR is the modeled completion of the first ack after
  the kill minus the kill time. The leader kill measures the metadata
  layer's re-election path the same way. Acceptance (CI ``--key-max``):
  both MTTRs stay under 50 modeled ms.

The §16 **minority-partition scenario** runs the same workload on a
5-replica metadata group with message-level network noise, carves the
leader into a 2-replica minority mid-run, and heals before the end:

* **Partitioned goodput ratio** — acked records per modeled second over the
  partitioned window, against the fault-free run's same window. The majority
  side elects and serves (pre-vote keeps doomed minority candidacies from
  perturbing terms), so availability holds. Acceptance: >= 0.8x.
* **Post-heal convergence** — modeled milliseconds of divergent-suffix
  reconciliation after heal (catch-up rounds x one request/reply RTT each).
  Acceptance (CI ``--key-max``): under 50 modeled ms.
* **Message-fault counters** — ``msgs_dropped`` / ``msgs_delayed`` /
  ``msgs_duplicated`` / ``fenced_rejections`` / ``lease_reads`` /
  ``lease_fallbacks`` surfaced through ``OpTally`` so the JSON records how
  much abuse the consensus layer absorbed and how the §18 read fast path
  split between lease-served and fallback.
* **Lease-read linearizability** — the partitioned run interleaves reads
  (served through ``read_state()``: lease-local on the fast path, fenced
  into the barrier fallback when the deposed leader's lease lapses) and
  records every append/read into the §16 ``History`` checker; the
  ``lease_reads_linearizable`` key is 1.0 iff the whole history admits a
  legal total order. This is the ISSUE's proof obligation that lease reads
  stay linearizable under partitions.

Both runs share the workload, the DES service model, and the arrival
process; only the fault plane differs — the ratios isolate the cost of the
faults themselves. ``BENCH_QUICK=1`` shrinks the run ~4x for CI smoke.

Run directly for the **seed sweep** (the scheduled extended-chaos lane):

    PYTHONPATH=src python -m benchmarks.bench_chaos --seeds 8 --json OUT.json

reports WORST-case (not mean) MTTR / goodput / convergence across seeds —
availability claims live or die on the tail seed, not the average one.
"""

from __future__ import annotations

import os
from typing import List, Optional

from repro.core import BoltSystem, FaultConfig, History, RetryPolicy
from repro.core.errors import BrokerCrashed
from repro.core.sim import (OpTally, Resource, ServiceTimes, Simulator,
                            summarize)

from .common import Row

QUICK = os.environ.get("BENCH_QUICK", "") not in ("", "0")

REC = b"c" * 1024
N_OPS = 400 if QUICK else 1600
RATE = 600.0                      # appends per modeled second
READ_EVERY = 8                    # interleaved reads exercise the GET path
KILL_BROKER_AT = 0.30             # fraction of the arrival span
KILL_LEADER_AT = 0.60
STORE_NOISE = 0.01                # ISSUE 7 acceptance: 1% store-op failure
SEED = 0xC4A05
PARTITION_AT = 0.35               # §16 scenario: leader into the minority...
HEAL_AT = 0.70                    # ...and healed before the run ends


def _kill_cfg(seed: int) -> FaultConfig:
    span = N_OPS / RATE
    return FaultConfig(
        seed=seed,
        store_put_error=STORE_NOISE,
        store_get_error=STORE_NOISE,
        store_delete_error=STORE_NOISE,
        # the kill targets broker 0 — the sticky client's connection —
        # so the MTTR path includes the detection failure, not a free
        # re-route around a broker the client never talked to
        schedule=((span * KILL_BROKER_AT, "kill_broker", 0),
                  (span * KILL_LEADER_AT, "kill_leader", None)))


def _partition_cfg(seed: int) -> FaultConfig:
    span = N_OPS / RATE
    return FaultConfig(
        seed=seed,
        net_drop=0.01, net_delay=0.01,           # §16 message-level noise on
        net_duplicate=0.005, net_reorder=0.005,  # every consensus link
        schedule=((span * PARTITION_AT, "partition", ((0, 1), (2, 3, 4))),
                  (span * HEAL_AT, "heal_network", None)))


def _build(cfg: Optional[FaultConfig], n_meta: int = 3) -> BoltSystem:
    system = BoltSystem(n_brokers=4, n_meta_replicas=n_meta, faults=cfg,
                        retry=RetryPolicy(attempts=8))
    # the DES hooks ride on the brokers (§8): every PUT/GET books service
    # time and queues on the shared store pool, so completion times are
    # modeled, deterministic, and machine-portable
    sim = Simulator()
    service = ServiceTimes()
    store_res = Resource(servers=64)
    for b in system.brokers:
        b.sim = sim
        b.service = service
        b.store_resource = store_res
    return system


class _StickyClient:
    """A client that holds one broker connection (as real clients do) and
    re-connects only after an attempt observes the death — so a broker kill
    costs a detection failure plus the §15 failover/backoff, all of which
    lands in the MTTR measurement instead of being routed around for free."""

    def __init__(self, system: BoltSystem) -> None:
        self.system = system
        self.cur = system.brokers[0]

    def _attempt(self, fn):
        def attempt(_a):
            b = self.cur
            if b.broker_id in self.system._dead:
                # re-connect for the NEXT attempt; THIS attempt is the
                # failed detection RPC the retry layer pays backoff for
                self.cur = self.system.live_broker(b)
                raise BrokerCrashed("client-held broker is dead",
                                    broker_id=b.broker_id)
            return fn(b)
        return self.system._retrying(attempt)

    def append(self, log_id: int, t: float):
        return self._attempt(lambda b: b.append(log_id, [REC], arrival=t))

    def read(self, log_id: int, lo: int, hi: int, t: float):
        return self._attempt(lambda b: b.read(log_id, lo, hi, arrival=t))

    def read_records(self, log_id: int, lo: int, hi: int, t: float):
        return self._attempt(
            lambda b: b.read_records(log_id, lo, hi, arrival=t))


def _run(faulted: bool, seed: int = SEED) -> dict:
    system = _build(_kill_cfg(seed) if faulted else None)
    root = system.metadata.propose(("create_root", "chaos"))
    client = _StickyClient(system)
    span = N_OPS / RATE
    kills = ([(span * KILL_BROKER_AT, "broker"),
              (span * KILL_LEADER_AT, "leader")] if faulted else [])
    mttr: dict = {}
    pending_kill: Optional[tuple] = None
    lat: List[float] = []
    makespan = 0.0
    read_hi = 0
    for i in range(N_OPS):
        t = i / RATE
        if faulted:
            if kills and t >= kills[0][0]:
                pending_kill = kills.pop(0)
            system.faults.advance(t)
        backoff0 = system.retry_stats.backoff_time
        if READ_EVERY and i % READ_EVERY == READ_EVERY - 1 and read_hi:
            _, done = client.read(root, max(0, read_hi - 16), read_hi, t)
        else:
            _, done = client.append(root, t)
            read_hi += 1
            # jittered pauses advance the client's clock even though the
            # DES store pool never sees them: charge them to the ack
            done += system.retry_stats.backoff_time - backoff0
            lat.append(done - t)
            if pending_kill is not None:
                mttr[pending_kill[1]] = done - pending_kill[0]
                pending_kill = None
        makespan = max(makespan, done)
    state = system.metadata.state
    assert state.tails.get(root)[0] == read_hi, "lost acked appends"
    out = {"p99": summarize(sorted(lat))[2],
           "goodput": read_hi / makespan,
           "retries": system.retry_stats.retries,
           "backoff": system.retry_stats.backoff_time,
           "mttr": mttr}
    if faulted:
        out["injected"] = system.faults.total_injected
        out["elections"] = system.metadata.elections
        out["failovers"] = system.broker_failovers
    return out


def _run_partition(seed: int = SEED) -> dict:
    """The §16 minority-partition scenario: a 5-replica metadata group with
    message-level network noise; the leader's side loses quorum mid-run and
    the majority side must elect and keep serving; heal before the end and
    measure divergent-suffix reconciliation. Runs a fault-free twin over the
    identical arrival process for the window-goodput comparison."""
    span = N_OPS / RATE
    t_part, t_heal = span * PARTITION_AT, span * HEAL_AT
    out: dict = {}
    for mode in ("clean", "partitioned"):
        cfg = _partition_cfg(seed) if mode == "partitioned" else None
        system = _build(cfg, n_meta=5)
        root = system.metadata.propose(("create_root", "chaos"))
        client = _StickyClient(system)
        before = OpTally.capture(system)
        hist = History()                       # §16/§18: lease-read history
        hist.register_log(root, 0)
        acks: List[tuple] = []                 # (arrival, modeled completion)
        read_hi = 0
        for i in range(N_OPS):
            t = i / RATE
            if cfg is not None:
                system.faults.advance(t)
            backoff0 = system.retry_stats.backoff_time
            if READ_EVERY and i % READ_EVERY == READ_EVERY - 1 and read_hi:
                # reads ride read_state(): lease-local on the fast path,
                # barrier fallback once the partition fences the old lease
                lo = max(0, read_hi - 16)
                op = hist.invoke("read", root, (lo, read_hi))
                recs, _ = client.read_records(root, lo, read_hi, t)
                hist.resolve(op, tuple(recs))
            else:
                op = hist.invoke("append", root, (REC,))
                pos, done = client.append(root, t)
                hist.resolve(op, tuple(pos))
                read_hi += 1
                done += system.retry_stats.backoff_time - backoff0
                acks.append((t, done))
        # goodput over the partitioned window only: acked records whose
        # arrival fell inside [t_part, t_heal), per modeled second until the
        # last of them completed — the window where the minority-side leader
        # is useless and every ack must come from the majority side
        window = [(t, d) for t, d in acks if t_part <= t < t_heal]
        out[mode] = len(window) / (max(d for _, d in window) - t_part)
        if cfg is not None:
            first = next((d for t, d in acks if t >= t_part), None)
            out["mttr"] = (first - t_part) if first is not None else float("inf")
            system.faults.advance(span)        # the heal event has fired
            rounds = system.metadata.sync_followers()
            # reconciliation cost: each catch-up round is one AppendEntries
            # request/reply exchange on the modeled network
            out["converge_ms"] = rounds * 2 * ServiceTimes().net_rtt * 1e3
            assert system.metadata.check_convergence(), "no convergence after heal"
            state = system.metadata.state
            assert state.tails.get(root)[0] == read_hi, "lost acked appends"
            # the final full read settles the history; the checker then rules
            # on the WHOLE partitioned trace — every lease-served read, every
            # fenced fallback, every retried append
            op = hist.invoke("read", root, (0, read_hi))
            recs, _ = client.read_records(root, 0, read_hi, span)
            hist.resolve(op, tuple(recs))
            verdict = hist.check()
            assert verdict.ok, f"lease-read history not linearizable: " \
                               f"{verdict.reason}"
            out["linearizable"] = 1.0
            tally = OpTally.capture(system).delta(before)
            out["counters"] = {k: getattr(tally, k) for k in
                               ("msgs_dropped", "msgs_delayed",
                                "msgs_duplicated", "fenced_rejections",
                                "lease_reads", "lease_fallbacks")}
            out["elections"] = system.metadata.elections
    out["ratio"] = out["partitioned"] / out["clean"]
    return out


def bench_chaos() -> List[Row]:
    base = _run(faulted=False)
    chaos = _run(faulted=True)
    rows: List[Row] = []
    rows.append(("chaos/fault_free/p99_ack_ms", base["p99"] * 1e3,
                 f"{N_OPS} ops at {RATE:.0f}/s on the DES clock, no plane "
                 "attached (the byte-identical pre-§15 path)"))
    rows.append(("chaos/faulted/p99_ack_ms", chaos["p99"] * 1e3,
                 f"{STORE_NOISE * 100:.0f}% store noise + broker kill + "
                 f"leader kill: {chaos['injected']} faults injected, "
                 f"{chaos['retries']} retries, "
                 f"{chaos['backoff'] * 1e3:.1f}ms total backoff charged"))
    rows.append(("chaos/p99_ack_ratio", chaos["p99"] / base["p99"],
                 "tail cost of transparent recovery (dimensionless; CI "
                 "diffs it against the committed baseline)"))
    rows.append(("chaos/goodput_ratio", chaos["goodput"] / base["goodput"],
                 f"{chaos['goodput']:.0f}/s faulted vs {base['goodput']:.0f}/s "
                 "fault-free acked records per modeled second "
                 "(acceptance floor >= 0.9x)"))
    rows.append(("chaos/mttr/broker_kill_ms", chaos["mttr"]["broker"] * 1e3,
                 f"first ack after the scheduled broker kill: detection "
                 f"failure + §15 failover ({chaos['failovers']} staged "
                 "failovers) + backoff (ceiling 50 modeled ms)"))
    rows.append(("chaos/mttr/leader_kill_ms", chaos["mttr"]["leader"] * 1e3,
                 f"first ack after the scheduled leader kill: the metadata "
                 f"layer re-elected {chaos['elections']} time(s) inside the "
                 "propose path (ceiling 50 modeled ms)"))
    part = _run_partition()
    rows.append(("chaos/partition/goodput_ratio", part["ratio"],
                 f"{part['partitioned']:.0f}/s during the minority partition "
                 f"vs {part['clean']:.0f}/s fault-free over the same window: "
                 f"the majority side elected ({part['elections']} election(s))"
                 " and kept serving (acceptance floor >= 0.8x)"))
    rows.append(("chaos/partition/mttr_ms", part["mttr"] * 1e3,
                 "first ack after the partition fired: NoQuorum detection on "
                 "the minority leader + majority-side election + retry"))
    rows.append(("chaos/partition/converge_ms", part["converge_ms"],
                 "post-heal divergent-suffix reconciliation, modeled as one "
                 "request/reply RTT per catch-up round (ceiling 50 ms)"))
    c = part["counters"]
    rows.append(("chaos/partition/lease_reads_linearizable",
                 part["linearizable"],
                 f"§16 checker verdict on the full partitioned history: "
                 f"{c['lease_reads']} lease-served reads + "
                 f"{c['lease_fallbacks']} fenced fallbacks + every retried "
                 "append admit a legal total order (acceptance = 1.0, "
                 "CI --key-min)"))
    for key, n in sorted(part["counters"].items()):
        rows.append((f"chaos/partition/{key}", float(n),
                     "§16 message-plane abuse absorbed during the run "
                     "(surfaced via OpTally; deterministic per seed)"))
    return rows


def bench_chaos_sweep(seeds: int) -> List[Row]:
    """Worst-case (NOT mean) availability across ``seeds`` distinct fault
    sequences — the scheduled extended-chaos lane. One bad seed is one real
    unlucky deployment; averaging it away would hide exactly the tail the
    §15/§16 machinery exists to bound."""
    base = _run(faulted=False)                 # plane-free: seed-independent
    worst_goodput = worst_part_goodput = float("inf")
    worst_mttr = worst_converge = 0.0
    for i in range(seeds):
        seed = SEED ^ (i * 0x9E3779B1)
        chaos = _run(faulted=True, seed=seed)
        part = _run_partition(seed=seed)
        worst_goodput = min(worst_goodput, chaos["goodput"] / base["goodput"])
        worst_mttr = max(worst_mttr, chaos["mttr"]["broker"] * 1e3,
                         chaos["mttr"]["leader"] * 1e3,
                         part["mttr"] * 1e3)
        worst_part_goodput = min(worst_part_goodput, part["ratio"])
        worst_converge = max(worst_converge, part["converge_ms"])
    return [
        ("chaos/sweep/seeds", float(seeds),
         "distinct fault-plane seeds swept (kill schedule + partition "
         "scenario each)"),
        ("chaos/sweep/worst_goodput_ratio", worst_goodput,
         "min over seeds of faulted/fault-free goodput (floor 0.9)"),
        ("chaos/sweep/worst_partition_goodput_ratio", worst_part_goodput,
         "min over seeds of partitioned-window goodput ratio (floor 0.8)"),
        ("chaos/sweep/worst_mttr_ms", worst_mttr,
         "max over seeds and kill kinds incl. the partition MTTR "
         "(ceiling 50 modeled ms)"),
        ("chaos/sweep/worst_converge_ms", worst_converge,
         "max over seeds of post-heal reconciliation (ceiling 50 ms)"),
    ]


def main() -> None:
    import argparse
    import json

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--seeds", type=int, default=0,
                    help="sweep N seeds and report worst-case rows "
                         "(0 = single-seed bench_chaos rows)")
    ap.add_argument("--json", default=None,
                    help="also write {row_name: value} JSON to this path")
    args = ap.parse_args()
    rows = bench_chaos_sweep(args.seeds) if args.seeds else bench_chaos()
    print("name,us_per_call,derived")
    results = {}
    for row_name, val, derived in rows:
        print(f"{row_name},{val:.3f},{derived}", flush=True)
        results[row_name] = val
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)


if __name__ == "__main__":
    main()
