"""Read-path benchmarks (DESIGN.md §10, §18): the scan-oriented read plane.

Six families:

* ``read/lookup``       — Fig 10 revisited: single-position lookup latency vs
                          cFork nesting depth, with and without the
                          flattened-view cache (acceptance: >=5x at depth>=5).
* ``read/single_record``— byte amplification of a 1-record read out of a
                          ~1 MB group-commit segment: page-granular cache vs
                          the seed's whole-object fill.
* ``read/scan``         — cold/warm streaming scan throughput via
                          ``AgileLog.scan`` (scatter-gather + readahead).
* ``read/record_size``  — cold-scan throughput across record sizes.
* ``read/catchup``      — the agent-first pattern: a fresh cFork (cold broker
                          cache) bulk-reads its parent's history.
* ``read/lease``        — §18 lease-fenced local reads: with the fault plane
                          live, every tail/lookup/read resolution goes through
                          ``MetadataService.read_state()`` and must ride the
                          leader's lease WITHOUT a consensus round. The family
                          reports metadata proposals per read (acceptance:
                          ~0 on the fast path, CI ``--key-max``) and the
                          fraction of reads served from the lease.

Quick mode for CI smoke runs: ``BENCH_QUICK=1`` shrinks sizes ~8x.
``BENCH_STORE=file`` (CI) swaps the tmpdir-scoped fsync'ing backend in.
"""

from __future__ import annotations

import os
import time
from typing import List

from repro.core import BoltSystem
from repro.core.broker import GroupCommitConfig
from repro.core.metadata import MetadataState

from .common import Row, backend_kwargs, timeit

QUICK = os.environ.get("BENCH_QUICK", "") not in ("", "0")


def _deep_state(view_cache: bool, levels: int, per_level: int, batch: int = 512):
    """A `levels`-deep cFork chain, `per_level` records appended per level."""
    state = MetadataState(view_cache=view_cache)
    log_id = state.apply(("create_root", "r"))
    for depth in range(1, levels + 1):
        for start in range(0, per_level, batch):
            k = min(batch, per_level - start)
            state.apply(("append", log_id, f"o{depth}-{start}",
                         tuple(range(0, k * 8, 8)), tuple([8] * k)))
        log_id = state.apply(("cfork", log_id, False))
    return state, log_id


def _fill(system: BoltSystem, name: str, n_records: int, record: bytes,
          batch: int = 256):
    log = system.create_log(name)
    for start in range(0, n_records, batch):
        log.append_batch([record] * min(batch, n_records - start))
    system.flush()
    return log


def bench_read() -> List[Row]:
    rows: List[Row] = []
    levels = 7
    per_level = 2_500 if QUICK else 20_000
    n_calls = 500 if QUICK else 2_000

    # -- lookup vs depth: cached vs uncached resolver -----------------------
    lookup = {}
    for cached, tag in ((False, "uncached"), (True, "cached")):
        state, deepest = _deep_state(cached, levels, per_level)
        for depth_hit in (1, 3, 5, 7):
            pos = (levels - depth_hit) * per_level + per_level // 2
            us = timeit(lambda: state.read_spans(deepest, pos, pos + 1),
                        n=n_calls)
            lookup[(tag, depth_hit)] = us
            rows.append((f"read/lookup/{tag}/depth={depth_hit}", us,
                         "flattened-view cache" if cached else "chain walk"))
    for d in (5, 7):
        ratio = lookup[("uncached", d)] / lookup[("cached", d)]
        rows.append((f"read/lookup/speedup/depth={d}", ratio,
                     f"{ratio:.1f}x faster cached (acceptance >=5x)"))

    # -- single-record read out of a ~1MB segment: bytes fetched ------------
    seg_records = 64 if QUICK else 256
    rec4k = b"s" * 4096
    sys_ = BoltSystem(
        group_commit=GroupCommitConfig(max_records=seg_records,
                                       max_bytes=8 << 20),
        cache_page_bytes=64 << 10, readahead_bytes=0, **backend_kwargs())
    log = _fill(sys_, "seg", seg_records * 4, rec4k, batch=seg_records)
    seg_bytes = seg_records * len(rec4k)
    broker = log.broker
    b0 = broker.cache.bytes_fetched
    assert log.read(seg_records + 3, seg_records + 4) == [rec4k]
    fetched = broker.cache.bytes_fetched - b0
    rows.append(("read/single_record/bytes_fetched", float(fetched),
                 f"page-granular; whole-object fill = {seg_bytes} B "
                 f"({seg_bytes / max(1, fetched):.0f}x more)"))

    # -- cold/warm scan throughput ------------------------------------------
    n_records = 8_192 if QUICK else 65_536
    rec = b"x" * 256
    sys_ = BoltSystem(group_commit=GroupCommitConfig(max_records=256,
                                                     max_bytes=1 << 20),
                      **backend_kwargs())
    log = _fill(sys_, "scan", n_records, rec)
    total_mb = n_records * len(rec) / 1e6
    t0 = time.perf_counter()
    n = sum(1 for _ in log.scan(batch=1024))
    cold = time.perf_counter() - t0
    assert n == n_records
    t0 = time.perf_counter()
    for _ in log.scan(batch=1024):
        pass
    warm = time.perf_counter() - t0
    rows.append(("read/scan/cold", cold / n_records * 1e6,
                 f"{total_mb / cold:.0f} MB/s ({n_records} x 256B)"))
    rows.append(("read/scan/warm", warm / n_records * 1e6,
                 f"{total_mb / warm:.0f} MB/s ({cold / warm:.1f}x of cold)"))

    # -- record-size sweep (cold scans) -------------------------------------
    total_bytes = (2 << 20) if QUICK else (16 << 20)
    for size in (256, 4096, 65536):
        k = max(1, total_bytes // size)
        sys_ = BoltSystem(group_commit=GroupCommitConfig(max_records=256,
                                                         max_bytes=4 << 20),
                          **backend_kwargs())
        log = _fill(sys_, f"sz{size}", k, b"r" * size,
                    batch=min(256, max(1, (1 << 20) // size)))
        t0 = time.perf_counter()
        n = sum(1 for _ in log.scan(batch=max(64, 4096 // (size // 256 + 1))))
        dt = time.perf_counter() - t0
        assert n == k
        rows.append((f"read/record_size/{size}B", dt / k * 1e6,
                     f"{k * size / 1e6 / dt:.0f} MB/s cold"))

    # -- agent catch-up: fresh cFork bulk-reads parent history --------------
    sys_ = BoltSystem(group_commit=GroupCommitConfig(max_records=256,
                                                     max_bytes=1 << 20),
                      **backend_kwargs())
    root = _fill(sys_, "hist", n_records, rec)
    agent = root.cfork()          # different broker => cold object cache
    t0 = time.perf_counter()
    n = sum(1 for _ in agent.scan(batch=1024))
    dt = time.perf_counter() - t0
    assert n == n_records
    rows.append(("read/catchup/cfork_cold", dt / n_records * 1e6,
                 f"{n_records * len(rec) / 1e6 / dt:.0f} MB/s "
                 f"(broker {agent.broker.broker_id}, parent on "
                 f"{root.broker.broker_id})"))

    # -- §18 lease-fenced reads: consensus bypass on the fast path ----------
    # The plane must be live for leases to exist at all (plane=None is the
    # pre-§16 single-node path, where every read is trivially local).
    n_lease = 2_000 if QUICK else 10_000
    sys_ = BoltSystem(n_brokers=2, faults=True, **backend_kwargs())
    meta = sys_.metadata
    log = _fill(sys_, "lease", 4_096, rec, batch=256)
    p0, l0, f0 = meta.proposals, meta.lease_reads, meta.lease_fallbacks
    t0 = time.perf_counter()
    for i in range(n_lease):
        if i % 8 == 7:
            log.read(i % 4_000, i % 4_000 + 16)
        else:
            assert log.tail == 4_096
    dt = time.perf_counter() - t0
    proposals = meta.proposals - p0
    leased = meta.lease_reads - l0
    fellback = meta.lease_fallbacks - f0
    rows.append(("read/lease/us_per_read", dt / n_lease * 1e6,
                 f"tail+ranged reads via read_state() under the live plane "
                 f"({n_lease} reads)"))
    rows.append(("read/lease/proposals_per_read", proposals / n_lease,
                 f"{proposals} metadata proposals across {n_lease} reads — "
                 "the fast path rides the lease, not consensus "
                 "(acceptance ~0, CI --key-max)"))
    rows.append(("read/lease/fast_path_fraction", leased / max(1, leased + fellback),
                 f"{leased} lease reads, {fellback} fallbacks "
                 "(acceptance 1.0 in steady state, CI --key-min)"))
    return rows
