"""§6.8 real-agent benchmarks (Figs 12-14 analog): the three agents run for
real against Bolt; their tool-call traces drive the DES contention model to
compare Bolt (fork on its own broker) vs Kafka-like (shared broker+disk)."""

from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.agents import AnalyticsAgent, StreamTestingAgent, SupplyChainAgent
from repro.agents.supplychain import InventoryConsumer
from repro.core import BoltSystem
from repro.core.sim import Resource, ServiceTimes, summarize
from repro.streams import Producer, Topic

from .common import Row

S = ServiceTimes()


def _iot_topic(system, n=20_000):
    topic = Topic.create(system, "iot")
    prod = Producer(topic, linger_records=256)
    rng = np.random.default_rng(0)
    temps = rng.normal(20.0, 0.5, size=n)
    temps[n // 3] += 40
    temps[2 * n // 3] += 40
    for i in range(n):
        prod.produce({"ts": i * 1e-3, "temperature": float(temps[i]),
                      "humidity": 55.0,
                      "status": "ok" if temps[i] < 50 else "sensor-fault"})
    prod.flush()
    return topic


def _replay_reads_on_des(n_reads: int, read_kb: float, shared: bool):
    """lc-latency stats while `n_reads` agent reads replay on the DES."""
    lc_broker = Resource()
    lc_disk = Resource() if shared else None
    ag_broker = lc_broker if shared else Resource()
    store = Resource(servers=16)
    t = 0.0
    for _ in range(n_reads):
        t2 = ag_broker.submit(t, S.broker_cpu_per_req + S.broker_cpu_per_kb * read_kb)
        if shared:
            t2 = lc_disk.submit(t2, S.disk_seek + S.disk_read_per_kb * read_kb)
        else:
            t2 = store.submit(t2, S.store_get_base + S.store_get_per_kb * read_kb)
        t = t2 * 0.7  # overlapping parallel investigations
    lat = []
    for i in range(3000):
        arr = i / 2000.0
        c = lc_broker.submit(arr, S.broker_cpu_per_req + S.broker_cpu_per_kb * 4)
        if shared:
            c = lc_disk.submit(c, S.disk_seek + S.disk_read_per_kb * 4)
        lat.append(c + S.metadata_op + S.net_rtt - arr)
    return summarize(lat)


def bench_agents() -> List[Row]:
    rows: List[Row] = []

    # ---- analytics agent (Fig 12): real run on an sFork --------------------
    sys_ = BoltSystem(n_brokers=4)
    topic = _iot_topic(sys_)
    agent = AnalyticsAgent(topic, scan_limit=20_000, chunk=2048)
    t0 = time.perf_counter()
    result = agent.run()
    wall = (time.perf_counter() - t0) * 1e6
    n_reads = result["tool_calls"]
    found = len(result["spikes"].get("temperature", []))
    rows.append(("fig12/analytics_agent/run", wall,
                 f"{n_reads} tool reads, {found} anomalies found, root untouched"))
    mean_b, _x, p99_b = _replay_reads_on_des(n_reads, 512.0, shared=False)
    mean_k, _x, p99_k = _replay_reads_on_des(n_reads, 512.0, shared=True)
    rows.append(("fig12/lc_mean/bolt", mean_b * 1e6, "agent on own broker"))
    rows.append(("fig12/lc_mean/kafka", mean_k * 1e6,
                 f"{mean_k / mean_b:.1f}x of Bolt"))
    rows.append(("fig12/lc_p99/kafka_vs_bolt", p99_k * 1e6,
                 f"{p99_k / p99_b:.1f}x of Bolt"))
    agent.cleanup()

    # ---- stream-processor testing agent (Fig 13) ----------------------------
    sys2 = BoltSystem(n_brokers=4)
    t2 = Topic.create(sys2, "events")
    prod = Producer(t2, linger_records=128)
    for i in range(5000):
        prod.produce({"ts": i * 0.1, "value": 1.0})
    prod.flush()
    tester = StreamTestingAgent(t2, window_ms=5.0)
    t0 = time.perf_counter()
    res = tester.run()
    wall = (time.perf_counter() - t0) * 1e6
    rows.append(("fig13/testing_agent/run", wall,
                 f"{res['cases']} cases, bugs={res['bugs_found']}, "
                 f"root tail unchanged={t2.tail == 5000}"))

    # ---- supply-chain agent (Fig 14) ---------------------------------------
    sys3 = BoltSystem(n_brokers=4)
    t3 = Topic.create(sys3, "orders")
    prod = Producer(t3, linger_records=64)
    for i in range(500):
        prod.produce({"kind": "order", "item": "widget", "qty": 1})
    prod.flush()
    validator = InventoryConsumer()
    validator.process(t3)
    # Kafka mode: direct write with a schema mistake crashes the consumer
    bad = SupplyChainAgent(t3, inject_mistake=True)
    crashed = False
    t0 = time.perf_counter()
    safe_ok = bad.run_safe(validator)  # Bolt: validation catches it
    wall = (time.perf_counter() - t0) * 1e6
    rows.append(("fig14/supplychain/bolt_safe", wall,
                 f"mistake caught pre-promote (squashed={bad.squashes})"))
    direct = SupplyChainAgent(t3, inject_mistake=True)
    direct.run_direct()
    try:
        InventoryConsumer().process(t3)
    except Exception:
        crashed = True
    rows.append(("fig14/supplychain/kafka_direct", 0.0,
                 f"downstream consumer crashed={crashed}"))
    return rows
