"""cFork scaling benchmarks: Fig 8 (parent perf with many cForks) and Fig 9
(metadata-layer technique ablation: BoltNaiveCF vs Bolt-ET vs Bolt)."""

from __future__ import annotations

import time
from typing import List

from repro.core.metadata import MetadataState

from .common import Row

_BATCH = 256
_OFFS = tuple(range(0, _BATCH * 8, 8))
_LENS = tuple([8] * _BATCH)


def _metadata_append_tput(state: MetadataState, root: int, n_ops: int,
                          fork_ids: List[int]) -> float:
    """Append ops/s on the root, with interleaved tail reads on forks (the
    lazy path is only exercised when fork tails are observed)."""
    t0 = time.perf_counter()
    for i in range(n_ops):
        state.apply(("append", root, f"t{i}", _OFFS, _LENS))
        if fork_ids and i % 4 == 0:
            state.tail(fork_ids[i % len(fork_ids)])
    return n_ops / (time.perf_counter() - t0)


def bench_many_cforks() -> List[Row]:
    """Fig 8a: root append throughput with 0/10/100 cForks (Bolt)."""
    rows: List[Row] = []
    base = None
    for n_forks in (0, 10, 100):
        state = MetadataState(cf_mode="ltt")
        root = state.apply(("create_root", "r"))
        forks = [state.apply(("cfork", root, False)) for _ in range(n_forks)]
        tput = _metadata_append_tput(state, root, 2000, forks)
        if base is None:
            base = tput
        rows.append((f"fig8a/root_append/cforks={n_forks}", 1e6 / tput,
                     f"{tput:.0f} ops/s ({tput / base:.2f}x of no-fork)"))
    # Fig 8b: 32 root logs, 100 cForks each
    state = MetadataState(cf_mode="ltt")
    roots = [state.apply(("create_root", f"r{i}")) for i in range(32)]
    for r in roots:
        for _ in range(100):
            state.apply(("cfork", r, False))
    t0 = time.perf_counter()
    n = 2000
    for i in range(n):
        state.apply(("append", roots[i % 32], f"t{i}", _OFFS, _LENS))
    tput = n / (time.perf_counter() - t0)
    rows.append(("fig8b/32roots_100cforks_each", 1e6 / tput,
                 f"{tput:.0f} ops/s across 32 roots"))
    return rows


def bench_cfork_ablation() -> List[Row]:
    """Fig 9: metadata-layer throughput at 10/100/1000 cForks for
    BoltNaiveCF (index copies), Bolt-ET (eager tails), Bolt (lazy LTT)."""
    rows: List[Row] = []
    for n_forks in (10, 100, 1000):
        for mode, tag in (("naive", "BoltNaiveCF"), ("eager", "Bolt-ET"),
                          ("ltt", "Bolt")):
            if mode == "naive" and n_forks == 1000:
                n_ops = 50   # naive at 1000 forks is painfully slow by design
            else:
                n_ops = 600
            state = MetadataState(cf_mode=mode)
            root = state.apply(("create_root", "r"))
            forks = [state.apply(("cfork", root, False))
                     for _ in range(n_forks)]
            tput = _metadata_append_tput(state, root, n_ops, forks)
            rows.append((f"fig9/metadata_tput/{tag}/cforks={n_forks}",
                         1e6 / tput, f"{tput:.0f} append-batches/s"))
    return rows
