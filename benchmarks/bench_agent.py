"""Speculation sessions vs the hand-rolled fork/validate/promote loop
(DESIGN.md §12) — N agents committing against a hot producer.

Scenario: one hot ``orders`` root takes a producer record every
``PRODUCE_PERIOD`` seconds of *simulated* time while agents take turns
running validate-then-commit sessions against it (validate = read the last
``VALIDATE`` records of the fork; write = append a ``SUFFIX``-record batch;
commit). Both paths execute REAL operations against one BoltSystem — every
conflict comes from actual parent-tail advancement sequenced through the
metadata layer, not from a probability model — while a deterministic clock
books per-operation service times (:class:`ServiceTimes`) on the agent's
critical path and "pumps" the producer forward whenever the clock advances
(producer service time rides its own broker, §5.7, so only its *sequencing*
is visible to the agents).

The two client loops:

* ``session``    — ``log.speculate()`` + ``commit()``: the conditional
  ``promote_if`` closes the check-then-promote race in ONE proposal, and a
  conflict rebases by replaying the suffix ZERO-COPY (metadata-only
  re-appends of the already-durable segment) plus re-validating only the
  parent's delta via ``on_rebase``.
* ``handrolled`` — the pre-§12 client loop: cfork, full validation read,
  append (a fresh object PUT every attempt), a tail-check round, a separate
  promote round; on conflict squash and redo EVERYTHING. Records sequenced
  between its tail check and its promote are merged unvalidated (counted as
  ``tainted`` — the race ``promote_if`` exists to close).

Acceptance (ISSUE 4): session commit throughput >= 2x hand-rolled under the
contended producer. ``BENCH_QUICK=1`` shrinks the run ~4x for CI smoke.
"""

from __future__ import annotations

import os
from typing import List

from repro.core import BoltSystem, ConflictError
from repro.core.sim import OpTally, ServiceTimes

from .common import Row

QUICK = os.environ.get("BENCH_QUICK", "") not in ("", "0")

S = ServiceTimes()
REC_BYTES = 256
PRODUCE_PERIOD = 2.5e-3     # one producer record per 2.5ms of simulated time
VALIDATE = 256              # records (re)read to validate an attempt
SUFFIX = 8                  # records an agent commits per session
N_AGENTS = 4
MAX_ROUNDS = 12             # bound on promote attempts per commit, both paths

PRODUCER_REC = b"o" * REC_BYTES
AGENT_REC = b"s" * REC_BYTES


class _AgentClock:
    """Deterministic agent-side clock: each op advances simulated time by its
    modeled service cost, then lets the producer catch up to the new time —
    so contention emerges from real sequencing, at honest rates."""

    def __init__(self, pump) -> None:
        self.t = 0.0
        self._pump = pump

    def op(self, cost: float) -> None:
        self.t += cost
        self._pump(self.t)

    def propose(self) -> None:
        """One metadata round (cfork/squash/promote/promote_if/tail check)."""
        self.op(S.metadata_op + S.net_rtt)

    def put_append(self, nbytes: int) -> None:
        """Data-plane append: broker CPU + object PUT + sequencing round."""
        self.op(S.broker_cpu_per_req + S.broker_cpu_per_kb * nbytes / 1024
                + S.store_put_base + S.store_put_per_kb * nbytes / 1024
                + S.metadata_op + S.net_rtt)

    def replay_append(self) -> None:
        """Zero-copy re-append: sequencing round only, no PUT (§12)."""
        self.op(S.broker_cpu_per_req + S.metadata_op + S.net_rtt)

    def read(self, nbytes: int) -> None:
        """Warm validation read: broker CPU on the bytes + cached metadata."""
        self.op(S.broker_cpu_per_req + S.broker_cpu_per_kb * nbytes / 1024
                + S.metadata_op_cached + S.net_rtt)


def _run_mode(session: bool, n_commits: int) -> dict:
    system = BoltSystem(n_brokers=N_AGENTS + 1)
    root = system.create_log("orders")
    # prefill so the validation window is always full
    for start in range(0, VALIDATE * 2, 256):
        root.append_batch([PRODUCER_REC] * 256)
    produced = [0]

    def pump(t: float) -> None:
        want = int(t / PRODUCE_PERIOD)
        while produced[0] < want:
            root.append(PRODUCER_REC)    # withheld while a hold is active
            produced[0] += 1

    clock = _AgentClock(pump)
    before = OpTally.capture(system)
    produced_before = produced[0]
    commits = conflicts = rebases = failures = tainted = 0
    t0 = clock.t

    def one_session() -> None:
        nonlocal commits, conflicts, rebases, failures

        def on_rebase(s, lo, hi):
            # book what the rebase actually did: squash + cfork + one
            # zero-copy replay of the suffix batch, then re-validate ONLY
            # the parent's delta, then the retried promote_if round
            clock.propose()
            clock.propose()
            clock.replay_append()
            delta = s.read(lo, hi)
            clock.read(sum(len(r) for r in delta))
            clock.propose()
            return True

        clock.propose()                              # cfork round
        s = root.speculate(max_rebases=MAX_ROUNDS - 1, on_rebase=on_rebase)
        hi = s.tail
        s.read(max(0, hi - VALIDATE), hi)            # full validation, once
        clock.read(VALIDATE * REC_BYTES)
        s.append_batch([AGENT_REC] * SUFFIX)
        clock.put_append(SUFFIX * REC_BYTES)
        clock.propose()                              # promote_if, attempt 1
        try:
            res = s.commit()
            commits += 1
            conflicts += res.attempts - 1
            rebases += res.rebases
        except ConflictError as e:                   # budget exhausted
            conflicts += e.attempts
            failures += 1

    def one_handrolled() -> None:
        nonlocal commits, conflicts, failures, tainted
        for _attempt in range(MAX_ROUNDS):
            clock.propose()                          # cfork round
            fork = root.cfork(promotable=True)
            info = system.metadata.state.fork_info(fork.log_id)
            fp = info.fork_point
            hi = fork.tail
            fork.read(max(0, hi - VALIDATE), hi)     # FULL re-validation
            clock.read(VALIDATE * REC_BYTES)
            fork.append_batch([AGENT_REC] * SUFFIX)  # fresh PUT every attempt
            clock.put_append(SUFFIX * REC_BYTES)
            clock.propose()                          # tail-check round
            if system.metadata.state.tail(root.log_id) > fp:
                conflicts += 1
                clock.propose()                      # squash round
                fork.squash()
                continue
            produced_at_check = produced[0]
            clock.propose()                          # promote round...
            fork.promote()                           # ...the unclosable race:
            tainted += produced[0] - produced_at_check   # merged unvalidated
            commits += 1
            return
        failures += 1

    while commits < n_commits:
        for _agent in range(N_AGENTS):
            if commits >= n_commits:
                break
            if session:
                one_session()
            else:
                one_handrolled()

    elapsed = clock.t - t0
    tally = OpTally.capture(system).delta(before)
    agent_puts = tally.puts - (produced[0] - produced_before)  # minus producer
    return {
        "us_per_commit": elapsed / max(1, commits) * 1e6,
        "commits": commits, "conflicts": conflicts, "rebases": rebases,
        "failures": failures, "tainted": tainted,
        "produced": produced[0] - produced_before,
        "agent_puts_per_commit": agent_puts / max(1, commits),
        "replays": tally.replays, "spec_replayed": tally.spec_replayed,
    }


def bench_agent() -> List[Row]:
    n_commits = 12 if QUICK else 48
    ses = _run_mode(session=True, n_commits=n_commits)
    hand = _run_mode(session=False, n_commits=n_commits)

    rows: List[Row] = []
    rows.append(("agent/session/us_per_commit", ses["us_per_commit"],
                 f"{ses['commits']} commits, {ses['conflicts']} conflicts, "
                 f"{ses['rebases']} rebases ({ses['spec_replayed']} records "
                 f"replayed zero-copy), {ses['failures']} failures, "
                 f"{ses['produced']} producer records contending"))
    rows.append(("agent/handrolled/us_per_commit", hand["us_per_commit"],
                 f"{hand['commits']} commits, {hand['conflicts']} conflicts "
                 f"(full re-validation each), {hand['failures']} failures, "
                 f"{hand['tainted']} records merged unvalidated (check/promote "
                 f"race), {hand['produced']} producer records contending"))
    speedup = hand["us_per_commit"] / ses["us_per_commit"]
    rows.append(("agent/commit_tput/speedup", speedup,
                 f"{speedup:.2f}x session vs hand-rolled (acceptance >= 2x)"))
    rows.append(("agent/session/puts_per_commit", ses["agent_puts_per_commit"],
                 f"vs {hand['agent_puts_per_commit']:.2f} hand-rolled: rebase "
                 "replay re-sequences durable segments instead of re-PUTting"))
    rows.append(("agent/handrolled/puts_per_commit",
                 hand["agent_puts_per_commit"],
                 "every conflict re-PUTs the suffix object"))
    rows.append(("agent/session/rebases_per_commit",
                 ses["rebases"] / max(1, ses["commits"]),
                 f"{ses['replays']} zero-copy replay proposals total"))
    return rows
