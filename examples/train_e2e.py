"""End-to-end training on the log-backed data plane, with a mid-run crash and
an exact resume — the fault-tolerance deliverable at CPU scale.

The log is a durable shared SERVICE the training job is a client of:
checkpoints are log forks (DESIGN.md §17), so "crash" kills the client while
the BoltSystem survives, and the restarted job re-attaches by name — finds
its token stream, replays the checkpoint catalog, reaps any fork a crashed
save orphaned, and resumes the identical batch stream.

    PYTHONPATH=src python examples/train_e2e.py [--steps 150]
(The production-shape variant of this loop is what the multi-pod dry-run
compiles; see repro/launch/dryrun.py.)
"""

import argparse

from repro.core import BoltSystem
from repro.launch.train import run

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=150)
args = ap.parse_args()

# ONE shared-log service outlives both training-client "processes"
system = BoltSystem(n_brokers=4, gc=True)

# phase 1: train, checkpointing every 25 steps — then "crash" at step N
half = args.steps // 2
print(f"=== phase 1: train to step {half}, then crash ===")
losses1, _, _ = run(steps=half, d_model=128, n_layers=4, system=system,
                    ckpt_every=25, log_every=25)

# phase 2: a fresh client re-attaches to the same service, restores the
# latest catalog manifest + data cursor, and continues the identical stream
print("=== phase 2: restart from the last checkpoint ===")
losses2, _, _ = run(steps=args.steps, d_model=128, n_layers=4, system=system,
                    ckpt_every=25, log_every=25, resume=True)

print(f"phase1 final {losses1[-1]:.4f} -> phase2 final {losses2[-1]:.4f} "
      f"(loss kept falling across the restart: {losses2[-1] < losses1[-1]})")
