"""End-to-end training on the log-backed data plane, with a mid-run crash and
an exact resume — the fault-tolerance deliverable at CPU scale.

    PYTHONPATH=src python examples/train_e2e.py [--steps 150]
(The production-shape variant of this loop is what the multi-pod dry-run
compiles; see repro/launch/dryrun.py.)
"""

import argparse

from repro.core.objectstore import MemoryObjectStore
from repro.launch.train import run

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=150)
args = ap.parse_args()

store = MemoryObjectStore()

# phase 1: train, checkpointing every 50 steps — then "crash" at step N
half = args.steps // 2
print(f"=== phase 1: train to step {half}, then crash ===")
losses1, _, _ = run(steps=half, d_model=128, n_layers=4, store=store,
                    ckpt_every=25, log_every=25)

# phase 2: a fresh process restores the atomic manifest + data cursor and
# continues the identical batch stream
print("=== phase 2: restart from the last checkpoint ===")
losses2, _, _ = run(steps=args.steps, d_model=128, n_layers=4, store=store,
                    ckpt_every=25, log_every=25, resume=True)

print(f"phase1 final {losses1[-1]:.4f} -> phase2 final {losses2[-1]:.4f} "
      f"(loss kept falling across the restart: {losses2[-1] < losses1[-1]})")
