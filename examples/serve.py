"""Serving ON the log (DESIGN.md §17): requests stream in through a
subscription, a batched engine decodes and appends per-token response records
the subscribers demux, and a speculative decoder runs each draft rollout as a
``log.speculate()`` session — byte-identical to sequential greedy decode.

    PYTHONPATH=src python examples/serve.py
"""

import time

import jax
import numpy as np

from repro.core import BoltSystem
from repro.models.config import ModelConfig
from repro.models.lm import init_params
from repro.serve import (ModelDraft, ModelTarget, ServeEngine,
                         SpeculativeDecoder, decode_response,
                         sequential_decode)
from repro.streams import Producer, Topic

cfg = ModelConfig(name="serve-demo", n_layers=2, d_model=64, n_heads=2,
                  n_kv_heads=1, d_ff=128, vocab_size=256,
                  tie_embeddings=True, attn_chunk=32)
params = init_params(cfg, jax.random.key(0))

# ---- request/response topics on the shared log -------------------------------
system = BoltSystem(n_brokers=4)
requests = Topic.create(system, "requests")
responses = Topic.create(system, "responses")
prod = Producer(requests)
rng = np.random.default_rng(0)
BATCH, PROMPT, GEN = 4, 8, 12
for rid in range(BATCH):
    prod.produce({"id": f"req-{rid}",
                  "prompt": [int(t) for t in rng.integers(2, 256, PROMPT)]})
prod.flush()

# ---- batched engine: subscription in, per-token records out ------------------
eng = ServeEngine(cfg, params, requests, responses, batch_size=BATCH)
t0 = time.time()
served = eng.poll_and_serve(gen_tokens=GEN)
dt = time.time() - t0
print(f"engine served {served} requests, {GEN} tokens each in {dt:.2f}s "
      f"({served * GEN / max(dt, 1e-9):.1f} tok/s)")
assert eng.poll_and_serve() == 0      # durable cursor: nothing left to serve

# clients demux the shared response stream by (id, seq)
log = responses.log
out = decode_response(log.read(0, log.visible_tail))
assert set(out) == {f"req-{r}" for r in range(BATCH)}
assert all(len(toks) == GEN for toks in out.values())
print("first response:", out["req-0"][:8], "...")

# ---- speculative decoding: each rollout is a log.speculate() session ---------
dcfg = ModelConfig(name="serve-draft", n_layers=1, d_model=32, n_heads=2,
                   n_kv_heads=1, d_ff=64, vocab_size=256,
                   tie_embeddings=True, attn_chunk=32)
target = ModelTarget(cfg, params, stats=system.serve_stats)
draft = ModelDraft(dcfg, init_params(dcfg, jax.random.key(1)),
                   stats=system.serve_stats)
spec_log = system.create_log("spec-responses")
dec = SpeculativeDecoder(target, draft, k=2, stats=system.serve_stats)

prompt = [int(t) for t in rng.integers(2, 256, PROMPT)]
ref = sequential_decode(target, prompt, GEN)
res = dec.decode_request(spec_log, "spec-0", prompt, GEN)
assert res.tokens == ref              # greedy speculative decoding is exact
view = decode_response(spec_log.read(0, spec_log.visible_tail))
assert view == {"spec-0": ref}        # ... and so is the stream itself
rejected = sum(1 for r in res.rollouts if r.rejected)
print(f"speculative: {len(res.tokens)} tokens in {len(res.rollouts)} "
      f"speculate() sessions ({rejected} aborted with no trace, "
      f"acceptance {res.acceptance:.2f}) — byte-identical to sequential")
