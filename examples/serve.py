"""Batched serving: requests stream in through the log, decode runs with a KV
cache, responses stream back out — the serving-side end-to-end driver.

    PYTHONPATH=src python examples/serve.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import BoltSystem
from repro.models.config import ModelConfig
from repro.models.lm import decode_step, forward, init_caches, init_params
from repro.streams import Consumer, Producer, Topic

cfg = ModelConfig(name="serve-demo", n_layers=4, d_model=128, n_heads=4,
                  n_kv_heads=2, d_ff=256, vocab_size=1024,
                  tie_embeddings=True, attn_chunk=64)
params = init_params(cfg, jax.random.key(0))

# ---- request/response streams on the shared log ------------------------------
system = BoltSystem(n_brokers=4)
requests = Topic.create(system, "requests")
responses = Topic.create(system, "responses")
prod = Producer(requests)
rng = np.random.default_rng(0)
BATCH, PROMPT, GEN = 4, 16, 24
for rid in range(BATCH):
    prod.produce({"id": rid,
                  "prompt": [int(t) for t in rng.integers(2, 1024, PROMPT)]})
prod.flush()

# ---- serve loop: poll a batch, prefill, decode -------------------------------
consumer = Consumer(requests)
batch = consumer.poll(BATCH)
tokens = jnp.asarray([r["prompt"] for r in batch], jnp.int32)

t0 = time.time()
caches = init_caches(cfg, BATCH, PROMPT + GEN)
step = jax.jit(lambda p, c, tok, pos: decode_step(cfg, p, c, tok, pos))
# prefill token-by-token through the decode path (tiny prompt; a production
# prefill uses forward(want_caches=True) — exercised by the dry-run cells)
logits = None
for t in range(PROMPT):
    logits, caches = step(params, caches, tokens[:, t:t + 1],
                          jnp.asarray(t, jnp.int32))
out = [jnp.argmax(logits[:, -1, :cfg.vocab_size], axis=-1)]
for t in range(PROMPT, PROMPT + GEN - 1):
    logits, caches = step(params, caches, out[-1][:, None],
                          jnp.asarray(t, jnp.int32))
    out.append(jnp.argmax(logits[:, -1, :cfg.vocab_size], axis=-1))
gen = jnp.stack(out, axis=1)
dt = time.time() - t0

resp = Producer(responses)
for rid, row in enumerate(np.asarray(gen)):
    resp.produce({"id": rid, "tokens": [int(t) for t in row]})
resp.flush()
print(f"served {BATCH} requests, {GEN} tokens each in {dt:.2f}s "
      f"({BATCH * GEN / dt:.1f} tok/s)")
print("responses on stream:", responses.tail)
check = Consumer(responses).poll(BATCH)
print("first response:", check[0]["tokens"][:8], "...")
