"""The paper's three agentic applications (§6.8) end to end.

    PYTHONPATH=src python examples/agents_on_streams.py
"""

import numpy as np

from repro.agents import AnalyticsAgent, StreamTestingAgent, SupplyChainAgent
from repro.agents.supplychain import InventoryConsumer
from repro.core import BoltSystem
from repro.streams import Producer, Topic

system = BoltSystem(n_brokers=4)

# ---------------------------------------------------------- analytics (sFork)
iot = Topic.create(system, "iot")
prod = Producer(iot, linger_records=128)
rng = np.random.default_rng(0)
for i in range(5000):
    temp = float(rng.normal(20, 0.5)) + (40.0 if i in (1200, 3900) else 0.0)
    prod.produce({"ts": i / 1000, "temperature": temp, "humidity": 55.0,
                  "status": "ok" if temp < 50 else "sensor-fault"})
prod.flush()

agent = AnalyticsAgent(iot, scan_limit=5000, chunk=512)
report = agent.run()
print("[analytics] anomalies:", report["spikes"])
print("[analytics] correlated with status faults:", report["correlated"])
print("[analytics] root log untouched:", iot.tail == 5000)
agent.cleanup()

# ------------------------------------------------ testing (non-promotable cFork)
events = Topic.create(system, "events")
prod = Producer(events, linger_records=128)
for i in range(2000):
    prod.produce({"ts": i * 0.1, "value": 1.0})
prod.flush()

tester = StreamTestingAgent(events, window_ms=5.0)
res = tester.run()
print("[testing] cases:", [r.name for r in res["reports"]])
print("[testing] bugs found:", res["bugs_found"])
print("[testing] no test event leaked:", events.tail == 2000)

# --------------------------------------------- supply chain (promotable cFork)
orders = Topic.create(system, "orders")
prod = Producer(orders, linger_records=32)
for _ in range(60):
    prod.produce({"kind": "order", "item": "widget", "qty": 1})
prod.flush()
validator = InventoryConsumer()
validator.process(orders)

bad = SupplyChainAgent(orders, inject_mistake=True)
ok = bad.run_safe(validator)
print("[supply-chain] mistake caught before promote:", not ok)

good = SupplyChainAgent(orders)
ok = good.run_safe(validator)
downstream = InventoryConsumer()
downstream.process(orders)
print("[supply-chain] promoted restock; inventory:", downstream.inventory)

# ------------------------------------------- tailing subscription (DESIGN.md §12)
# a downstream job follows the orders stream push-style: the committed
# restock events arrive linearizably interleaved with the orders
from repro.streams import Consumer  # noqa: E402

follower = Consumer(orders, group="follower")
kinds = {}
for batch in follower.stream(follow=False):
    for rec in batch:
        kinds[rec["kind"]] = kinds.get(rec["kind"], 0) + 1
follower.commit()
print("[subscribe] drained", follower.offset, "records by kind:", kinds)

# --------------------------------------------- segment GC (DESIGN.md §13)
# the agents above churned forks constantly (sFork scans, what-if cForks,
# speculation aborts); without reclamation every dead fork's segments
# would sit in shared storage forever. One drain returns storage to the
# live working set — and the safety harness guarantees it never touches a
# byte any surviving log can still read.
before = system.store.total_bytes
stats = system.gc()
print(f"[gc] reclaimed {stats.objects_reclaimed} dead segment objects "
      f"({stats.bytes_reclaimed} B): store {before} -> "
      f"{system.store.total_bytes} B, {stats.tracked} live objects tracked")
