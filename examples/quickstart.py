"""Quickstart: the AgileLog abstraction in 60 lines (paper §4.1, Fig. 2).

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import BoltSystem
from repro.core.errors import ForkBlocked

system = BoltSystem(n_brokers=4)
log = system.create_log("orders")

# 1. the traditional shared-log API
for i in range(5):
    log.append(f"order-{i}".encode())
print("root:", log.read(0, log.tail))

# 2. continuous fork (Fig 2a/2b): inherits live appends, private writes
agent_view = log.cfork()
log.append(b"order-5")                      # lands on the parent...
agent_view.log if False else None
print("cfork sees parent append:", agent_view.read(5, 6))   # ...and the fork
agent_view.append(b"agent-note")            # private to the fork
print("parent tail:", log.tail, "| fork tail:", agent_view.tail)

# 3. severed fork from a past offset (Fig 2c/2d): frozen what-if sandbox
snapshot = log.sfork(past=2)
print("sfork snapshot:", snapshot.read(0, snapshot.tail))

# 4. promotable cFork: isolate -> validate -> promote (Fig 2e)
candidate = log.cfork(promotable=True)
candidate.append(b"restock-widget")
log.append(b"order-6")                      # producers keep appending
try:
    log.read(0, log.tail)                   # ...but reads beyond fp block
except ForkBlocked as e:
    print("parent read blocked during validation:", type(e).__name__)
# validation = read the fork: history + live orders + agent writes, interleaved
print("validation view:", candidate.read(5, candidate.tail))
candidate.promote()
print("after promote:", log.read(5, log.tail))

# 5. exploration: many promotable forks, first promote wins
a = log.cfork(promotable=True)
b = log.cfork(promotable=True)
a.append(b"path-A")
b.append(b"path-B")
a.promote()                                 # b is squashed automatically
print("chosen path:", log.read(log.tail - 1, log.tail))
print("metadata bytes:", system.metadata.state.metadata_bytes())
