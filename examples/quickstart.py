"""Quickstart: the AgileLog abstraction + the agent-session API in 70 lines
(paper §4.1 Fig. 2; DESIGN.md §12).

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import BoltSystem, ConflictError
from repro.core.errors import ForkBlocked

system = BoltSystem(n_brokers=4)
log = system.create_log("orders")

# 1. appends return unified receipts (per-call mode: resolved immediately)
receipts = [log.append(f"order-{i}".encode()) for i in range(5)]
print("positions:", [r.position() for r in receipts])
print("root:", log.read(0, log.tail))

# 2. continuous fork (Fig 2a/2b): inherits live appends, private writes
agent_view = log.cfork()
log.append(b"order-5")                      # lands on the parent...
print("cfork sees parent append:", agent_view.read(5, 6))   # ...and the fork
agent_view.append(b"agent-note")            # private to the fork
print("parent tail:", log.tail, "| fork tail:", agent_view.tail)

# 3. severed fork from a past offset (Fig 2c/2d): frozen what-if sandbox
snapshot = log.sfork(past=2)
print("sfork snapshot:", snapshot.read(0, snapshot.tail))

# 4. speculation session: the isolate -> validate -> promote loop (Fig 2e)
#    as ONE primitive — commit() is atomic and auto-rebases if producers
#    appended concurrently (replaying the speculative suffix zero-copy)
with log.speculate() as s:
    s.append(b"restock-widget")
    r = log.append(b"order-6")              # producers keep appending...
    print("producer position withheld during speculation:", r.withheld)
    try:
        log.read(0, log.tail)               # ...but reads beyond fp block
    except ForkBlocked as e:
        print("parent read blocked during validation:", type(e).__name__)
    # validation = read the fork: history + live orders + agent writes
    print("validation view:", s.read(5, s.tail))
    result = s.commit()                     # conflict -> rebase -> retry
    print(f"committed at {list(result.positions)} after "
          f"{result.rebases} rebase(s)")
print("after commit:", log.read(5, log.tail))

# 5. exploration: competing speculations — first commit wins, the loser's
#    commit raises ConflictError with fork-point diagnostics
a = log.speculate(max_rebases=0)
b = log.speculate(max_rebases=0)
a.append(b"path-A")
b.append(b"path-B")
a.commit()
try:
    b.commit()
except ConflictError as e:
    print("losing path rejected:", e)
print("chosen path:", log.read(log.tail - 1, log.tail))

# 6. tailing subscription: follow the stream push-style
sub = log.subscribe(from_pos=0, batch=4, follow=False)
for batch in sub:
    print("subscription batch:", batch)
log.append(b"order-7")
print("next poll sees the new record:", sub.poll())
print("metadata bytes:", system.metadata.state.metadata_bytes())
